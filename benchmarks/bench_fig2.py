"""Paper Fig 2: evolution of the mean-bias ratio R (and mu~v1 alignment)
across depth and training steps — R should grow with training while staying
aligned with the dominant spectral direction."""
from __future__ import annotations

import numpy as np

from repro.core import analysis
from .common import emit
from .figs_common import (
    CKPT_STEPS,
    capture_layer_inputs,
    ensure_trained,
    eval_batch,
    model_and_data,
)


def run() -> dict:
    ckpts = ensure_trained()
    model, data = model_and_data()
    batch = eval_batch(data)
    out = {}
    for step in CKPT_STEPS:
        acts = capture_layer_inputs(model, ckpts[step], batch)
        rs = [float(analysis.mean_bias_ratio(x)) for x in acts]
        cos = [float(analysis.spectral_alignment(x)["cos_mu_vk"][0])
               for x in acts]
        out[step] = {"R_per_layer": rs, "cos_mu_v1_per_layer": cos}
        emit(f"fig2/step{step}", 0.0,
             f"mean_R={np.mean(rs):.4f};max_R={np.max(rs):.4f};"
             f"mean_cos={np.mean(cos):.3f}")
    # headline: R grows with training
    growth = np.mean(out[CKPT_STEPS[-1]]["R_per_layer"]) / max(
        np.mean(out[CKPT_STEPS[0]]["R_per_layer"]), 1e-9)
    emit("fig2/R_growth_late_over_early", 0.0, f"ratio={growth:.2f}")
    out["growth"] = float(growth)
    return out


if __name__ == "__main__":
    run()

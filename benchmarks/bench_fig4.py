"""Paper Fig 4 (+Appendix C): outlier attribution — mean vs residual squared
share of the top-0.1% activation entries, early vs late, shallow vs deep —
plus the Appendix C tail contraction after mean removal."""
from __future__ import annotations

from repro.core import analysis
from .common import emit
from .figs_common import (
    CKPT_STEPS,
    capture_layer_inputs,
    ensure_trained,
    eval_batch,
    model_and_data,
)


def run() -> dict:
    ckpts = ensure_trained()
    model, data = model_and_data()
    batch = eval_batch(data)
    out = {}
    for tag, step in [("early", CKPT_STEPS[0]), ("late", CKPT_STEPS[-1])]:
        acts = capture_layer_inputs(model, ckpts[step], batch)
        for lname, x in [("shallow", acts[1]), ("deep", acts[-2])]:
            att = analysis.outlier_attribution(x)
            tail = analysis.tail_contraction(x)
            key = f"{tag}/{lname}"
            out[key] = {
                "median_rho_mean": float(att["median_rho_mean"]),
                "median_rho_res": float(att["median_rho_res"]),
                "tail_q999_raw": tail["raw_q"],
                "tail_q999_res": tail["res_q"],
            }
            emit(f"fig4/{key}", 0.0,
                 f"rho_mean={att['median_rho_mean']:.3f};"
                 f"rho_res={att['median_rho_res']:.3f};"
                 f"tail_contraction={tail['res_q'] / max(tail['raw_q'], 1e-9):.3f}")
    return out


if __name__ == "__main__":
    run()

"""Paper Fig 3: operator-level mean-bias amplification — R traced across
input -> +attention -> +FFN stages of a block, early vs late checkpoints,
plus adjacent-stage mean-direction cosine (directional reshaping)."""
from __future__ import annotations

import numpy as np

from repro.core import analysis
from .common import emit
from .figs_common import (
    CKPT_STEPS,
    capture_operator_stages,
    ensure_trained,
    eval_batch,
    model_and_data,
)


def _mean_dir(x: np.ndarray) -> np.ndarray:
    mu = x.mean(0)
    return mu / max(np.linalg.norm(mu), 1e-30)


def run() -> dict:
    ckpts = ensure_trained()
    model, data = model_and_data()
    batch = eval_batch(data)
    layer = model.cfg.num_layers // 2
    out = {}
    for tag, step in [("early", CKPT_STEPS[0]), ("late", CKPT_STEPS[-1])]:
        stages = capture_operator_stages(model, ckpts[step], batch, layer)
        names = ["input", "post_attn", "post_ffn"]
        rs = {n: float(analysis.mean_bias_ratio(stages[n])) for n in names}
        dirs = {n: _mean_dir(stages[n]) for n in names}
        cos_attn = float(abs(dirs["input"] @ dirs["post_attn"]))
        cos_ffn = float(abs(dirs["post_attn"] @ dirs["post_ffn"]))
        out[tag] = {"R": rs, "cos_in_attn": cos_attn, "cos_attn_ffn": cos_ffn}
        emit(f"fig3/{tag}", 0.0,
             f"R_in={rs['input']:.4f};R_attn={rs['post_attn']:.4f};"
             f"R_ffn={rs['post_ffn']:.4f};"
             f"dir_cos_attn={cos_attn:.3f};dir_cos_ffn={cos_ffn:.3f}")
    return out


if __name__ == "__main__":
    run()

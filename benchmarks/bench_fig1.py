"""Paper Fig 1 (+App A): three-panel mean-bias evidence — spectral spike,
one-sided token alignment, mu~v1 alignment — on trained activations."""
from __future__ import annotations

import numpy as np

from repro.core import analysis
from .common import emit
from .figs_common import (
    CKPT_STEPS,
    capture_layer_inputs,
    ensure_trained,
    eval_batch,
    model_and_data,
)


def run() -> dict:
    ckpts = ensure_trained()
    model, data = model_and_data()
    batch = eval_batch(data)
    params = ckpts[CKPT_STEPS[-1]]
    acts = capture_layer_inputs(model, params, batch)
    out = {}
    for name, x in [("layer0", acts[0]), ("deep", acts[-2])]:
        spec = analysis.spectral_alignment(x)
        cos_mu, cos_v2 = analysis.token_mean_cosine(x)
        row = {
            "sigma1_over_sigma2": float(spec["singular_values"][0]
                                        / max(spec["singular_values"][1], 1e-9)),
            "cos_mu_v1": float(spec["cos_mu_vk"][0]),
            "cos_mu_v2": float(spec["cos_mu_vk"][1]),
            "beta1": float(abs(spec["beta_k"][0])),
            "frac_tokens_positive_mu": float((cos_mu > 0).mean()),
            "frac_tokens_positive_v2": float((cos_v2 > 0).mean()),
        }
        out[name] = row
        emit(
            f"fig1/{name}", 0.0,
            f"cos_mu_v1={row['cos_mu_v1']:.3f};"
            f"spike={row['sigma1_over_sigma2']:.2f};"
            f"one_sided={row['frac_tokens_positive_mu']:.3f}",
        )
    return out


if __name__ == "__main__":
    run()

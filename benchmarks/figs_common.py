"""Shared infrastructure for the paper-figure benchmarks (Figs 1-5).

Trains the reduced paper model (qwen3-0.6b family) once, checkpointing at
early/mid/late steps, and exposes activation / output-gradient capture at
arbitrary layers — the raw material for every §2 diagnostic.
"""
from __future__ import annotations

import os
from typing import Dict, List, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import reduced
from repro.core.qgemm import recipe
from repro.data.pipeline import DataConfig, TokenStream
from repro.models.layers import QuantCtx, rms_norm
from repro.models.model import Model
from repro.models.transformer import attn_ffn_block_apply
from repro.optim import adamw
from repro.train import checkpoint
from repro.train.trainer import TrainConfig, init_train_state, make_train_step

CKPT_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                        "bench_model")
CKPT_STEPS = [20, 200, 600]
_TOTAL = CKPT_STEPS[-1]


def model_and_data() -> Tuple[Model, TokenStream]:
    cfg = reduced("qwen3-0.6b", remat=False)
    model = Model(cfg)
    data = TokenStream(DataConfig(seed=21, batch_size=8, seq_len=128,
                                  vocab_size=cfg.vocab_size, chain_alpha=7.0,
                                  n_states=48))
    return model, data


def ensure_trained() -> Dict[int, dict]:
    """Train once (bf16 recipe — we analyze ACTIVATION structure, which the
    paper measures on its BF16/quantized runs alike), checkpointing at
    CKPT_STEPS. Returns {step: params}."""
    model, data = model_and_data()
    have = set(checkpoint.all_steps(CKPT_DIR))
    if not set(CKPT_STEPS) <= have:
        tcfg = TrainConfig(
            quant_mode="bf16",
            optimizer=adamw.OptimizerConfig(peak_lr=3e-3, warmup_steps=20,
                                            total_steps=_TOTAL,
                                            weight_decay=0.01),
        )
        params, opt = init_train_state(model, tcfg, jax.random.key(0))
        step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=(0, 1))
        for i in range(_TOTAL):
            batch = jax.tree.map(jnp.asarray, data.batch(i))
            params, opt, _ = step_fn(params, opt, batch, jax.random.key(i))
            if (i + 1) in CKPT_STEPS:
                checkpoint.save(CKPT_DIR, i + 1, params, opt, keep=0)
    out = {}
    params_t, opt_t = _templates(model)
    for s in CKPT_STEPS:
        p, _, _ = checkpoint.restore(CKPT_DIR, params_t, opt_t, step=s)
        out[s] = p
    return out


def _templates(model: Model):
    params = jax.eval_shape(model.init, jax.random.key(0))
    opt = jax.eval_shape(adamw.init_state, params)
    return params, opt


def capture_layer_inputs(model: Model, params, batch) -> List[np.ndarray]:
    """Flattened (l, d) FFN-block inputs per layer (paper: 'FFN-input
    activations'), plus the final-norm input."""
    cfg = model.cfg
    ctx = QuantCtx(recipe("bf16"), jax.random.key(0))
    x, positions = model._embed_inputs(params, batch)
    acts = []
    for i in range(cfg.num_layers):
        p_l = jax.tree.map(lambda a: a[i], params["layers"])
        acts.append(np.asarray(
            x.reshape(-1, cfg.d_model), np.float32))
        x, _, _ = attn_ffn_block_apply(
            p_l, x, positions, QuantCtx(ctx.cfg, jax.random.fold_in(ctx.key, i)),
            cfg, None, None,
        )
    acts.append(np.asarray(x.reshape(-1, cfg.d_model), np.float32))
    return acts


def capture_operator_stages(model: Model, params, batch, layer: int
                            ) -> Dict[str, np.ndarray]:
    """Stage-wise activations through one block: input -> +attn -> +ffn
    (paper Fig 3's operator-level trace)."""
    from repro.models.attention import gqa_apply
    from repro.models.layers import ffn_apply

    cfg = model.cfg
    ctx = QuantCtx(recipe("bf16"), jax.random.key(0))
    x, positions = model._embed_inputs(params, batch)
    for i in range(layer):
        p_l = jax.tree.map(lambda a: a[i], params["layers"])
        x, _, _ = attn_ffn_block_apply(
            p_l, x, positions, QuantCtx(ctx.cfg, jax.random.fold_in(ctx.key, i)),
            cfg, None, None,
        )
    p_l = jax.tree.map(lambda a: a[layer], params["layers"])
    d = cfg.d_model
    stages = {"input": x}
    h = rms_norm(x, p_l["ln1"])
    a, _ = gqa_apply(p_l["attn"], h, positions, ctx.child(1), cfg)
    x1 = x + a
    stages["post_attn"] = x1
    h2 = rms_norm(x1, p_l["ln2"])
    f = ffn_apply(p_l["ffn"], h2, ctx.child(2), cfg.ffn_type)
    stages["post_ffn"] = x1 + f
    return {k: np.asarray(v.reshape(-1, d), np.float32)
            for k, v in stages.items()}


def capture_output_gradient(model: Model, params, batch, layer: int
                            ) -> np.ndarray:
    """dL/d(layer input) — an output-gradient matrix of the preceding GeMM
    stack (Appendix D's object), flattened to (l, d)."""
    cfg = model.cfg
    ctx = QuantCtx(recipe("bf16"), jax.random.key(0))
    x0, positions = model._embed_inputs(params, batch)

    def head_from(x):
        for i in range(layer, cfg.num_layers):
            p_l = jax.tree.map(lambda a: a[i], params["layers"])
            x, _, _ = attn_ffn_block_apply(
                p_l, x, positions,
                QuantCtx(ctx.cfg, jax.random.fold_in(ctx.key, i)), cfg, None,
                None,
            )
        logits = model._lm_head(params, x, ctx)
        lg = logits.astype(jnp.float32)
        targets = batch["tokens"][:, 1:]
        logz = jax.scipy.special.logsumexp(lg[:, :-1], axis=-1)
        gold = jnp.take_along_axis(lg[:, :-1], targets[..., None], -1)[..., 0]
        return jnp.mean(logz - gold)

    x = x0
    for i in range(layer):
        p_l = jax.tree.map(lambda a: a[i], params["layers"])
        x, _, _ = attn_ffn_block_apply(
            p_l, x, positions, QuantCtx(ctx.cfg, jax.random.fold_in(ctx.key, i)),
            cfg, None, None,
        )
    g = jax.grad(head_from)(x)
    return np.asarray(g.reshape(-1, cfg.d_model), np.float32)


def eval_batch(data: TokenStream, step: int = 10_000):
    return jax.tree.map(jnp.asarray, data.batch(step))
